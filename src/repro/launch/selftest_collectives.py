"""Multi-device self-test for core.lowering — run as a subprocess.

``python -m repro.launch.selftest_collectives`` forces 8 fake CPU devices
(BEFORE importing jax) and validates every collective schedule in
``repro.core.lowering`` against the psum/broadcast oracle under shard_map.
Prints ``OK`` on success; any assertion failure exits nonzero.  Kept as a
module (not a test file) so the main pytest process keeps 1 device.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402
from repro.compat import shard_map  # noqa: E402
from repro.core import lowering  # noqa: E402


def _run_1d(fn, x, n=8):
    mesh = jax.make_mesh((n,), ("i",))
    f = shard_map(
        fn, mesh=mesh, in_specs=P("i"), out_specs=P("i"), check_vma=False
    )
    return np.asarray(jax.jit(f)(x))


def main() -> None:
    rng = np.random.default_rng(0)

    for n in (8,):
        for shape in ((8, 4), (8, 16, 3)):
            x = rng.normal(size=shape).astype(np.float32)
            per = x.reshape(n, -1)
            total = per.sum(axis=0)

            # tree_allreduce == sum on every rank
            out = _run_1d(lambda v: lowering.tree_allreduce(v, "i"), x)
            np.testing.assert_allclose(
                out.reshape(n, -1), np.tile(total, (n, 1)), rtol=1e-5
            )

            # tree_reduce: rank 0 row holds the sum
            out = _run_1d(lambda v: lowering.tree_reduce(v, "i"), x)
            np.testing.assert_allclose(out.reshape(n, -1)[0], total, rtol=1e-5)

            # tree_broadcast: everyone ends with rank 0's row
            out = _run_1d(lambda v: lowering.tree_broadcast(v, "i"), x)
            np.testing.assert_allclose(
                out.reshape(n, -1), np.tile(per[0], (n, 1)), rtol=1e-6
            )

            # ring == psum oracle
            out = _run_1d(lambda v: lowering.ring_allreduce(v, "i"), x)
            np.testing.assert_allclose(
                out.reshape(n, -1), np.tile(total, (n, 1)), rtol=1e-5
            )

    # hierarchical on a (2,4) mesh == psum over both axes
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    x = rng.normal(size=(8, 4)).astype(np.float32)  # 8 = 2*4 shards of (1,4)

    def hier(v):
        return lowering.hierarchical_allreduce(v, "data", "pod", scatter_dimension=1)

    f = shard_map(
        hier, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
        check_vma=False,
    )
    out = np.asarray(jax.jit(f)(x))
    total = x.reshape(8, 1, 4).sum(axis=0)
    np.testing.assert_allclose(out.reshape(8, 1, 4), np.tile(total, (8, 1, 1)), rtol=1e-5)

    # allreduce_by_schedule dispatch: all three agree on a (2,4) mesh
    for schedule in lowering.GRAD_SYNC_SCHEDULES:
        def sync(v, s=schedule):
            return lowering.allreduce_by_schedule(
                v, s, data_axes=("pod", "data")
            )

        f = shard_map(
            sync, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
            check_vma=False,
        )
        out = np.asarray(jax.jit(f)(x))
        np.testing.assert_allclose(
            out.reshape(8, 1, 4), np.tile(total, (8, 1, 1)), rtol=1e-5,
            err_msg=f"schedule={schedule}",
        )

    # sync_gradients over a pytree, mean semantics
    grads = {
        "w": rng.normal(size=(8, 4)).astype(np.float32),
        "b": rng.normal(size=(8,)).astype(np.float32),
    }

    def sync_tree(g):
        return lowering.sync_gradients(g, "hierarchical", ("pod", "data"))

    f = shard_map(
        sync_tree, mesh=mesh,
        in_specs=({"w": P(("pod", "data")), "b": P(("pod", "data"))},),
        out_specs={"w": P(("pod", "data")), "b": P(("pod", "data"))},
        check_vma=False,
    )
    out = jax.jit(f)(grads)
    np.testing.assert_allclose(
        np.asarray(out["w"]).reshape(8, 1, 4),
        np.tile(grads["w"].reshape(8, 1, 4).mean(axis=0), (8, 1, 1)),
        rtol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(out["b"]).reshape(8, 1),
        np.tile(grads["b"].reshape(8, 1).mean(axis=0), (8, 1)),
        rtol=1e-5,
    )

    print("OK")


if __name__ == "__main__":
    main()
