"""Multi-device self-test for the shard_map distributed GEMM (subprocess).

Validates the TPU lowering of Listing 1 on a (2, 4) fake-device mesh for both
reduction schedules, against the dense numpy product.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.linalg.distributed import distributed_gemm_shardmap  # noqa: E402


def main() -> None:
    rng = np.random.default_rng(0)
    mesh = jax.make_mesh((2, 4), ("p", "q"))
    for m, k, n in ((8, 8, 8), (16, 32, 8), (64, 16, 24)):
        A = rng.normal(size=(m, k)).astype(np.float32)
        B = rng.normal(size=(k, n)).astype(np.float32)
        for schedule in ("tree", "ring"):
            fn = distributed_gemm_shardmap(mesh, schedule=schedule)
            out = np.asarray(fn(A, B))
            np.testing.assert_allclose(
                out, A @ B, rtol=2e-4, atol=2e-4,
                err_msg=f"schedule={schedule} shape={(m, k, n)}",
            )
    print("OK")


if __name__ == "__main__":
    main()
