"""Production mesh builders (functions, never module-level state — importing
this module must not initialise jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None, n_model: int = 1):
    """A small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if n_data is None:
        n_data = n // n_model
    assert n_data * n_model <= n, (n_data, n_model, n)
    if n_model > 1:
        return jax.make_mesh((n_data, n_model), ("data", "model"))
    return jax.make_mesh((n_data,), ("data",))
