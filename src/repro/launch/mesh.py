"""Production mesh builders + the topology cost model.

Mesh builders are functions, never module-level state — importing this
module must not initialise jax device state.

The :class:`Topology` cost model prices the LocalExecutor's simulated
transfers in *time* (per-hop latency + per-byte bandwidth over a
configurable interconnect shape), which is what makes collective ablations
("tree" vs "naive") and execution-backend ablations comparable beyond raw
message counts: ``stats.estimated_makespan(make_topology("ring", 8))``
charges each concurrent transfer round the maximum of its hops.
"""

from __future__ import annotations

import dataclasses
import math

import jax


@dataclasses.dataclass(frozen=True)
class Topology:
    """Interconnect cost model: hop distance × latency + bytes / bandwidth.

    ``kind``:
      * ``"flat"``     — full crossbar, every pair 1 hop (the paper's
        idealised machine; message counts *are* the cost);
      * ``"ring"``     — 1-D torus, hop count is the shorter arc (the
        TPU-pod-slice-like neighbour fabric);
      * ``"fat-tree"`` — ``arity``-ary switch tree over the ranks; a hop
        count of ``2·h`` reaches the lowest common switch at height ``h``
        (the classic datacenter fabric — uniform bandwidth, non-uniform
        latency).

    ``latency_s`` is charged per hop, ``bandwidth_Bps`` per byte end-to-end
    (links are full-duplex and non-blocking; contention is modelled only
    through the round structure of the transfer stream).  ``flops_per_s``
    is each rank's compute rate: when positive,
    ``ExecutionStats.estimated_makespan`` prices every wavefront level's
    critical-path ``OpNode.flops`` in seconds alongside the communication
    rounds; the default 0 keeps makespans communication-only.
    """

    kind: str
    n_nodes: int
    latency_s: float = 1e-6
    bandwidth_Bps: float = 10e9
    arity: int = 4
    flops_per_s: float = 0.0

    def __post_init__(self):
        assert self.kind in ("flat", "ring", "fat-tree"), self.kind
        assert self.n_nodes >= 1 and self.arity >= 2

    def hops(self, src: int, dst: int) -> int:
        """Link hops between two ranks under this topology."""
        if src == dst:
            return 0
        if self.kind == "flat":
            return 1
        if self.kind == "ring":
            d = abs(src - dst)
            return min(d, self.n_nodes - d)
        # fat-tree: climb to the lowest common switch, then descend
        h = 1
        span = self.arity
        while src // span != dst // span:
            span *= self.arity
            h += 1
        return 2 * h

    @property
    def diameter(self) -> int:
        """Worst-case hop count between any two ranks."""
        if self.n_nodes == 1:
            return 0
        if self.kind == "flat":
            return 1
        if self.kind == "ring":
            return self.n_nodes // 2
        return 2 * max(1, math.ceil(math.log(self.n_nodes, self.arity)))

    def transfer_time(self, src: int, dst: int, nbytes: int) -> float:
        """Seconds to move ``nbytes`` from ``src`` to ``dst`` (α–β model)."""
        h = self.hops(src, dst)
        if h == 0:
            return 0.0
        return h * self.latency_s + nbytes / self.bandwidth_Bps

    def calibrate(self, samples) -> "Topology":
        """Fit this topology's constants to *measured* samples.

        ``samples`` is an iterable of dicts of two shapes, freely mixed:

        * compute — ``{"flops": F, "seconds": s}``: one op body (or level)
          that retired ``F`` flops in ``s`` seconds; fitted as
          ``flops_per_s = ΣF / Σs`` (rate of the pooled sample, so long
          runs weigh more than noisy short ones);
        * transfer — ``{"nbytes": B, "hops": h, "seconds": s}``: one
          measured ship of ``B`` bytes over ``h`` link hops (``hops``
          defaults to 1); fitted by least squares to the α–β model
          ``s = h·α + B·β``, clamped to non-negative α and positive β.

        Returns a new frozen :class:`Topology` (constants not covered by
        the samples keep their current values) — the bridge from the
        process-pool backend's *measured* wall-clock (see the calibration
        sweep in ``benchmarks/bench_dag_overhead.py``) to the simulated
        makespan model, closing the loop between estimated and real time.
        """
        comp_f = comp_s = 0.0
        xfer = []
        for s in samples:
            if "flops" in s:
                comp_f += float(s["flops"])
                comp_s += float(s["seconds"])
            elif "nbytes" in s:
                xfer.append((float(s.get("hops", 1)), float(s["nbytes"]),
                             float(s["seconds"])))
        changes = {}
        if comp_f > 0.0 and comp_s > 0.0:
            changes["flops_per_s"] = comp_f / comp_s
        if xfer:
            if len(xfer) == 1 or len({(h, b) for h, b, _ in xfer}) == 1:
                # one distinct (hops, nbytes) point cannot split α from β:
                # attribute the mean to bandwidth, keep the current latency
                h, b, t = xfer[0]
                ts = [t for _h, _b, t in xfer]
                residual = max(1e-12,
                               sum(ts) / len(ts) - h * self.latency_s)
                if b > 0.0:
                    changes["bandwidth_Bps"] = b / residual
            else:
                # least squares for s = h·α + b·β over all samples
                shh = sum(h * h for h, _b, _t in xfer)
                sbb = sum(b * b for _h, b, _t in xfer)
                shb = sum(h * b for h, b, _t in xfer)
                sht = sum(h * t for h, _b, t in xfer)
                sbt = sum(b * t for _h, b, t in xfer)
                det = shh * sbb - shb * shb
                if det > 0.0:
                    alpha = (sht * sbb - sbt * shb) / det
                    beta = (sbt * shh - sht * shb) / det
                    changes["latency_s"] = max(0.0, alpha)
                    if beta > 0.0:
                        changes["bandwidth_Bps"] = 1.0 / beta
        return dataclasses.replace(self, **changes) if changes else self


def make_topology(kind: str = "flat", n_nodes: int = 1, *,
                  latency_s: float = 1e-6, bandwidth_Bps: float = 10e9,
                  arity: int = 4, flops_per_s: float = 0.0) -> Topology:
    """Build a :class:`Topology` cost model (see class docstring for kinds)."""
    return Topology(kind=kind, n_nodes=n_nodes, latency_s=latency_s,
                    bandwidth_Bps=bandwidth_Bps, arity=arity,
                    flops_per_s=flops_per_s)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips ("data", "model").
    Multi-pod: (2, 16, 16) = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None, n_model: int = 1):
    """A small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if n_data is None:
        n_data = n // n_model
    assert n_data * n_model <= n, (n_data, n_model, n)
    if n_model > 1:
        return jax.make_mesh((n_data, n_model), ("data", "model"))
    return jax.make_mesh((n_data,), ("data",))
