"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch gemma_7b
    PYTHONPATH=src python -m repro.launch.dryrun --all

For each cell this AOT-compiles the real step function (train_step for
training shapes, prefill/decode for serving shapes) against the production
mesh with the full published model config — ShapeDtypeStructs only, no
allocation — and records:

* ``memory_analysis()``  (per-device argument/output/temp bytes — fits HBM?)
* ``cost_analysis()``    (per-device HLO FLOPs + bytes accessed)
* collective bytes by op kind, parsed from the post-SPMD HLO text

into ``benchmarks/results/dryrun/<mesh>_<arch>_<shape>.json`` (incremental:
existing cells are skipped unless --force). §Roofline reads these files.
"""

# MUST precede any jax import (device count locks on first init).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                      # noqa: E402
from repro.models import LanguageModel         # noqa: E402
from repro.optim import AdamW                  # noqa: E402
from repro.data import make_batch_specs        # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding import make_policy         # noqa: E402
from repro.train.step import make_train_step   # noqa: E402
from repro.train.serve import tree_state_shardings  # noqa: E402
from repro.sharding.constraints import use_policy   # noqa: E402

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results",
    "dryrun")

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string or tuple-of-shapes string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_GROUPS_RE = re.compile(
    r"replica_groups=(?:\[(\d+),(\d+)\]|\{\{([\d,]*)\})")


def _group_size(rhs: str) -> int:
    """Participant count of a collective from its replica_groups attr."""
    m = _GROUPS_RE.search(rhs)
    if not m:
        return 2  # conservative default
    if m.group(2) is not None:
        return max(int(m.group(2)), 1)       # iota form [n_groups, size]
    first = m.group(3)
    return max(len([x for x in first.split(",") if x != ""]), 1)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device *wire bytes* of every collective in the post-SPMD HLO.

    From each op's output shape O and participant count g (replica_groups):
      all-gather          O·(g−1)/g      (received; output = gathered)
      all-reduce          2·O·(g−1)/g    (ring: reduce-scatter + all-gather)
      reduce-scatter      O·(g−1)       (output = 1/g shard; input ≈ O·g)
      all-to-all          O·(g−1)/g
      collective-permute  O
    '-start' async forms are counted once ('-done' skipped).
    """
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", ls)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVES:
            mm = re.match(rf"(\(.*?\)|\S+)\s+{kind}(?:-start)?\(", rhs)
            if mm and f"{kind}-done" not in rhs:
                o = _shape_bytes(mm.group(1))
                g = _group_size(rhs)
                if kind == "all-gather" or kind == "all-to-all":
                    wire = o * (g - 1) / g
                elif kind == "all-reduce":
                    wire = 2 * o * (g - 1) / g
                elif kind == "reduce-scatter":
                    wire = o * (g - 1)
                else:  # collective-permute
                    wire = o
                out[kind]["bytes"] += int(wire)
                out[kind]["count"] += 1
                break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["wire_model"] = True
    return out


def long500k_eligible(cfg) -> bool:
    """Sub-quadratic archs only (full-attention archs skip, per DESIGN.md)."""
    return all(b in ("rglru", "mlstm", "slstm", "swa", "local_attn")
               for b in cfg.block_pattern)


def cells_for(cfg) -> list[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if long500k_eligible(cfg):
        cells.append("long_500k")
    return cells


# ---------------------------------------------------------------------------
# step builders (return (lowered, label) )
# ---------------------------------------------------------------------------

def lower_train(model, cfg, policy, seq_len, global_batch, *, remat=True,
                n_loss_chunks=16):
    optimizer = AdamW(learning_rate=1e-4)
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(optimizer.init, params_s)
    batch_specs = make_batch_specs(cfg, seq_len, global_batch)
    step = make_train_step(model, optimizer, policy, remat=remat,
                           n_loss_chunks=n_loss_chunks)
    jitted = step.jit_with(params_s, opt_s, batch_specs)
    return jitted.lower(params_s, opt_s, batch_specs)


def lower_prefill(model, cfg, policy, seq_len, global_batch):
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch_specs = make_batch_specs(cfg, seq_len, global_batch)

    def step(params, tokens, extras):
        with use_policy(policy):
            return model.prefill(
                params, tokens, s_max=seq_len,
                frames=extras.get("frames"), pixels=extras.get("pixels"))

    p_sh = policy.tree_param_shardings(params_s)
    dp = policy.dp_axes if policy.batch_sharded else None
    sp = policy.model_axis if policy.seq_sharded else None
    tok_sh = NamedSharding(policy.mesh, P(dp, sp))
    extras, extras_sh = {}, {}
    if "frames" in batch_specs:
        extras["frames"] = batch_specs["frames"]
        extras_sh["frames"] = NamedSharding(policy.mesh, P(dp, sp, None))
    if "pixels" in batch_specs:
        extras["pixels"] = batch_specs["pixels"]
        extras_sh["pixels"] = NamedSharding(policy.mesh, P(dp, None, None))
    out_s = jax.eval_shape(step, params_s, batch_specs["tokens"], extras)
    states_sh = tree_state_shardings(policy, out_s[1])
    logits_sh = NamedSharding(policy.mesh, P(dp, None, None))
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, tok_sh, extras_sh),
        out_shardings=(logits_sh, states_sh))
    return jitted.lower(params_s, batch_specs["tokens"], extras)


def lower_decode(model, cfg, policy, seq_len, global_batch):
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    enc_len = (seq_len // cfg.encoder_ratio) if cfg.encoder_layers else 0
    states_s = jax.eval_shape(
        lambda: model.init_states(global_batch, seq_len, enc_len=enc_len))
    dp = policy.dp_axes if policy.batch_sharded else None
    token_s = jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, states, token, pos):
        with use_policy(policy):
            return model.decode_step(params, states, token, pos)

    p_sh = policy.tree_param_shardings(params_s)
    st_sh = tree_state_shardings(policy, states_s)
    tok_sh = NamedSharding(policy.mesh, P(dp, None))
    pos_sh = NamedSharding(policy.mesh, P())
    logits_sh = NamedSharding(policy.mesh, P(dp, None, None))
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, st_sh, tok_sh, pos_sh),
        out_shardings=(logits_sh, st_sh),
        donate_argnums=(1,),          # cache updated in place
    )
    return jitted.lower(params_s, states_s, token_s, pos_s)


def _lower_for(model, cfg, policy, kind, seq_len, global_batch, remat):
    if kind == "train":
        return lower_train(model, cfg, policy, seq_len, global_batch,
                           remat=remat)
    if kind == "prefill":
        return lower_prefill(model, cfg, policy, seq_len, global_batch)
    return lower_decode(model, cfg, policy, seq_len, global_batch)


def _compile_stats(lowered) -> dict:
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    out = {
        "flops": float(cost.get("flops", -1)) if cost else -1.0,
        "bytes": float(cost.get("bytes accessed", -1)) if cost else -1.0,
        "collectives": parse_collective_bytes(compiled.as_text()),
    }
    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                out[k] = int(v)
    return out


def _meter(cfg, policy, kind, seq_len, global_batch) -> dict:
    """Exact per-device FLOPs/bytes/collectives by group extrapolation.

    XLA's cost analysis counts a while-loop body once, so the production
    (scan-based) artifact under-reports.  Metering compiles the same cell at
    1 and 2 pattern-periods of depth with every scan fully unrolled and the
    materialised-attention/single-chunk-loss paths (loop-free), then
    extrapolates linearly in depth:  total = base + (L/period)·per_period.
    The sLSTM time scan is the one loop that cannot unroll; its in-loop
    recurrence FLOPs are added analytically (see EXPERIMENTS.md §Dry-run).
    """
    import dataclasses

    period = cfg.pattern_period
    stats = []
    for k_groups in (1, 2):
        cfg_k = dataclasses.replace(
            cfg, n_layers=k_groups * period,
            encoder_layers=(k_groups * period if cfg.encoder_layers else 0))
        model_k = LanguageModel(cfg_k, meter=True)
        lowered = _lower_for(model_k, cfg_k, policy, kind, seq_len,
                             global_batch, remat=False)
        stats.append(_compile_stats(lowered))
    s1, s2 = stats
    ratio = cfg.n_layers / period

    def extrap(a, b):
        per = b - a
        if per <= 0:
            # fusion noise can make the 2-period artifact cheaper per-op;
            # fall back to linear scaling of the larger artifact
            return max(a, b) * ratio / 2.0
        return max(a - per, 0.0) + ratio * per

    coll = {}
    for key in _COLLECTIVES + ("total_bytes",):
        v1 = s1["collectives"][key]
        v2 = s2["collectives"][key]
        if isinstance(v1, dict):
            coll[key] = {
                "bytes": extrap(v1["bytes"], v2["bytes"]),
                "count": extrap(v1["count"], v2["count"]),
            }
        else:
            coll[key] = extrap(v1, v2)
    out = {
        "flops_per_device": extrap(s1["flops"], s2["flops"]),
        "bytes_per_device": extrap(s1["bytes"], s2["bytes"]),
        "collectives": coll,
    }
    # analytic sLSTM in-loop correction (per device: batch is dp-sharded;
    # the gathered time scan runs replicated over the model axis)
    n_slstm = sum(1 for i in range(cfg.n_layers)
                  if cfg.block_pattern[i % period] == "slstm")
    if n_slstm and kind != "decode":
        d = cfg.d_model
        dh = d // cfg.n_heads
        b_loc = global_batch // policy.dp_size if policy.batch_sharded \
            else global_batch
        per_layer = seq_len * b_loc * (8 * cfg.n_heads * dh * dh + 24 * d)
        out["flops_per_device"] += n_slstm * per_layer
        out["slstm_flop_correction"] = n_slstm * per_layer
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, *, remat=True,
             meter: bool = True, params_tp: bool = False,
             ring_cache: bool = False) -> dict:
    import dataclasses as _dc
    cfg = configs.get(arch)
    if ring_cache:
        cfg = _dc.replace(cfg, ring_cache=True)
    model = LanguageModel(cfg)
    seq_len, global_batch, kind = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    policy = make_policy(
        mesh,
        batch_sharded=(global_batch > 1),
        seq_sharded=(kind != "decode"),
        params_tp=params_tp and kind == "decode",
    )
    t0 = time.time()
    lowered = _lower_for(model, cfg, policy, kind, seq_len, global_batch,
                         remat)
    t_lower = time.time() - t0
    t0 = time.time()
    prod = _compile_stats(lowered)
    t_compile = time.time() - t0

    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "seq_len": seq_len, "global_batch": global_batch, "kind": kind,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "production": prod,
        "params": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    if meter:
        m = _meter(cfg, policy, kind, seq_len, global_batch)
        result.update(m)
    else:
        result["flops_per_device"] = prod["flops"]
        result["bytes_per_device"] = prod["bytes"]
        result["collectives"] = prod["collectives"]
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--decode-tp", action="store_true",
                    help="TP-sharded weights for decode cells (§Perf C1)")
    ap.add_argument("--ring-cache", action="store_true",
                    help="windowed ring KV cache for SWA decode (§Perf r4)")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    archs = [args.arch] if args.arch else list(configs.all_names())
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        cfg = configs.get(arch)
        shapes = [args.shape] if args.shape else cells_for(cfg)
        for shape in shapes:
            if shape == "long_500k" and not long500k_eligible(cfg):
                print(f"SKIP {arch} long_500k (full attention)")
                continue
            for mesh_kind in meshes:
                tag = f"{args.tag}_" if args.tag else ""
                fname = os.path.join(
                    args.out_dir, f"{tag}{mesh_kind}_{arch}_{shape}.json")
                if os.path.exists(fname) and not args.force:
                    print(f"have {fname}, skipping")
                    continue
                label = f"{arch} × {shape} × {mesh_kind}"
                print(f"=== {label} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mesh_kind,
                                   remat=not args.no_remat,
                                   params_tp=args.decode_tp,
                                   ring_cache=args.ring_cache)
                    with open(fname, "w") as f:
                        json.dump(res, f, indent=1)
                    print(f"    ok: compile {res['compile_s']}s, "
                          f"flops/dev {res['flops_per_device']:.3e}, "
                          f"coll {res['collectives']['total_bytes']/2**20:.0f} MiB",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((label, repr(e)))
                    with open(fname + ".fail", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"    FAIL: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for label, err in failures:
            print(f"  {label}: {err[:200]}")
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
