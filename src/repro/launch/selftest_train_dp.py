"""Multi-device self-test: explicit-DP training (tree/ring/hierarchical
grad-sync schedules) is numerically equivalent to single-stream training.

8 fake devices; gemma reduced config; 3 steps. The Bind-faithful tree
schedule, the torus-native ring, and the pod-aware hierarchical schedule
must all produce the same parameters as running the whole batch on one
logical stream (they are all exact mean-reductions).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import LanguageModel  # noqa: E402
from repro.optim import AdamW  # noqa: E402
from repro.data import SyntheticLMDataset  # noqa: E402
from repro.train.step import (  # noqa: E402
    make_train_step, make_manual_dp_train_step, init_error_state)


def tree_allclose(a, b, rtol, atol, msg):
    for (ka, la), (kb, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        np.testing.assert_allclose(
            np.asarray(la, np.float32), np.asarray(lb, np.float32),
            rtol=rtol, atol=atol, err_msg=f"{msg}: {ka}")


def main() -> None:
    cfg = configs.get("gemma_7b").reduced()
    model = LanguageModel(cfg)
    opt = AdamW(learning_rate=1e-3)
    data = SyntheticLMDataset(cfg.vocab_size, seq_len=32, global_batch=8)

    params0 = model.init(jax.random.PRNGKey(0))
    os0 = opt.init(params0)

    # reference: plain jit (single logical stream)
    ref_step = make_train_step(model, opt, None, donate=False)
    p_ref, os_ref = params0, os0
    for s in range(3):
        p_ref, os_ref, _ = ref_step(p_ref, os_ref, data.batch_at(s))

    # 1D mesh: tree & ring
    mesh1 = jax.make_mesh((8,), ("data",))
    for schedule in ("tree", "ring"):
        step = make_manual_dp_train_step(
            model, opt, mesh1, schedule=schedule, data_axes=("data",))
        p, os_, err = params0, os0, init_error_state(params0)
        for s in range(3):
            p, os_, loss, err = step(p, os_, data.batch_at(s), err)
        tree_allclose(p, p_ref, 2e-4, 2e-4, f"schedule={schedule}")
        print(f"schedule={schedule} OK loss={float(loss):.4f}")

    # 2D (pod, data) mesh: hierarchical + compressed-outer variants
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    step = make_manual_dp_train_step(
        model, opt, mesh2, schedule="hierarchical",
        data_axes=("pod", "data"))
    p, os_, err = params0, os0, init_error_state(params0)
    for s in range(3):
        p, os_, loss, err = step(p, os_, data.batch_at(s), err)
    tree_allclose(p, p_ref, 2e-4, 2e-4, "hierarchical")
    print(f"schedule=hierarchical OK loss={float(loss):.4f}")

    step = make_manual_dp_train_step(
        model, opt, mesh2, schedule="hierarchical",
        data_axes=("pod", "data"), compress_outer=True)
    p, os_, err = params0, os0, init_error_state(params0)
    for s in range(3):
        p, os_, loss, err = step(p, os_, data.batch_at(s), err)
    # int8 compression is approximate: looser bound, but must stay close
    tree_allclose(p, p_ref, 5e-2, 5e-3, "compressed")
    # error-feedback residual must be bounded by the quantisation grid
    for leaf in jax.tree_util.tree_leaves(err):
        assert float(jnp.abs(leaf).max()) < 1.0
    print(f"schedule=compressed OK loss={float(loss):.4f}")

    print("OK")


if __name__ == "__main__":
    main()
