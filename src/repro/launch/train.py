"""End-to-end training driver with checkpoint/restart + heartbeat.

    PYTHONPATH=src python -m repro.launch.train \
        --arch gemma_7b --reduced --steps 200 --batch 8 --seq 64 \
        --ckpt-dir /tmp/run1 --resume auto

Argument parsing happens *before* jax import so ``--fake-devices`` can set
XLA_FLAGS (used by the multi-device integration tests).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", choices=("auto", "never"), default="auto")
    ap.add_argument("--heartbeat", default=None)
    ap.add_argument("--log-file", default=None)
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="model-axis size when fake devices are used")
    ap.add_argument("--grad-sync", default="implicit",
                    choices=("implicit", "tree", "ring", "hierarchical"))
    ap.add_argument("--crash-at-step", type=int, default=None,
                    help="fault-injection hook for the integration test")
    ap.add_argument("--metrics-out", default=None)
    return ap.parse_args(argv)


def main(argv=None) -> None:
    args = parse_args(argv)
    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.models import LanguageModel
    from repro.optim import AdamW, warmup_cosine
    from repro.data import SyntheticLMDataset
    from repro.ckpt import CheckpointManager
    from repro.train.step import make_train_step, make_manual_dp_train_step
    from repro.runtime.supervisor import touch_heartbeat
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import make_policy

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LanguageModel(cfg)
    optimizer = AdamW(
        learning_rate=warmup_cosine(args.lr, args.warmup, args.steps))

    data = SyntheticLMDataset(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        enc_len=(args.seq // cfg.encoder_ratio if cfg.encoder_layers else 0),
        d_model=cfg.d_model if (cfg.encoder_layers or cfg.frontend) else 0,
        vision_tokens=cfg.vision_tokens if cfg.frontend == "vision" else 0,
    )

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = optimizer.init(params)

    n_dev = len(jax.devices())
    policy = None
    manual_step = None
    if args.grad_sync != "implicit" and n_dev > 1:
        mesh = make_host_mesh(n_data=n_dev)
        manual_step = make_manual_dp_train_step(
            model, optimizer, mesh, schedule=args.grad_sync)
        from repro.train.step import init_error_state
        err = init_error_state(params)
    elif n_dev > 1:
        mesh = make_host_mesh(
            n_data=n_dev // args.mesh_model, n_model=args.mesh_model)
        policy = make_policy(mesh)
    step_fn = make_train_step(model, optimizer, policy) \
        if manual_step is None else None

    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume == "auto" and ckpt.latest_step() is not None:
        (params, opt_state), extra = ckpt.restore((params, opt_state))
        start_step = int(extra["step"]) + 1
        print(f"[train] resumed from step {start_step - 1}", flush=True)

    log_f = open(args.log_file, "a") if args.log_file else None
    final_metrics = {}
    for step in range(start_step, args.steps):
        if args.crash_at_step is not None and step == args.crash_at_step:
            print(f"[train] injected crash at step {step}", flush=True)
            os._exit(42)
        batch = data.batch_at(step)
        if manual_step is not None:
            params, opt_state, loss, err = manual_step(
                params, opt_state, batch, err)
            metrics = {"loss": loss}
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if args.heartbeat:
            touch_heartbeat(args.heartbeat)
        if ckpt and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step, (params, opt_state), extra={"step": step})
        if step % 10 == 0 or step == args.steps - 1:
            final_metrics = {
                k: float(v) for k, v in metrics.items()
                if hasattr(v, "shape") or isinstance(v, (int, float))}
            line = json.dumps({"step": step, **final_metrics})
            print(f"[train] {line}", flush=True)
            if log_f:
                log_f.write(line + "\n")
                log_f.flush()
    if ckpt:
        ckpt.save(args.steps - 1, (params, opt_state),
                  extra={"step": args.steps - 1}, block=True)
        ckpt.wait()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"final": final_metrics}, f)
    print("[train] done", flush=True)


if __name__ == "__main__":
    main()
