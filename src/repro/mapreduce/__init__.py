"""Paper §IV-B: a MapReduce engine implemented on the Bind model."""

from .engine import KVPairs
from .sort import sort_integers

__all__ = ["KVPairs", "sort_integers"]
