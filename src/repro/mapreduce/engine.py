"""A "trivial" MapReduce engine over Bind (paper §IV-B, Listing 2).

The paper's point is that map / combine / **implicit shuffle** / reduce fall
out of the Bind model for free: map and reduce are placed ops; the shuffle is
nothing but the implicit transfers the runtime derives from "reduce of bucket
``b`` runs on ``owner(b)`` but its inputs were produced on mapper nodes".

Data model (columnar, vectorised — the TPU-friendly adaptation of the
paper's ``std::vector<std::pair<K, V>>``): a partition is a numpy array of
values; ``map`` emits (keys, values) arrays; the engine groups by key bucket.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro import core as bind


def _map_partition(values, map_fn):
    keys, vals = map_fn(values)
    order = np.argsort(keys, kind="stable")  # group rows by destination bucket
    return keys[order], vals[order]


def _extract_bucket(keys, vals, lo, hi):
    sel = slice(np.searchsorted(keys, lo), np.searchsorted(keys, hi))
    return vals[sel]


def _reduce_bucket(reduce_fn, bucket_id, dtype, *pieces):
    # dtype-stable even for empty buckets: an int64 job must never leak a
    # float64 empty (np.empty(0) defaults to float64 and would poison the
    # dtype promotion in collect()).
    if pieces:
        merged = np.concatenate(pieces)
    else:
        merged = np.empty(0, dtype=np.dtype(dtype) if dtype is not None else None)
    return reduce_fn(bucket_id, merged)


class KVPairs:
    """Distributed key/value collection: ``KVPairs(wf, partitions).map(f).reduce(g)``.

    ``partitions`` maps node rank → BindArray of that node's local values
    (the paper's ``local_map`` of documents).
    """

    def __init__(self, wf: bind.Workflow, partitions: dict[int, bind.BindArray]):
        self.wf = wf
        self.partitions = dict(partitions)

    @classmethod
    def from_arrays(cls, wf: bind.Workflow, arrays: Sequence[np.ndarray]) -> "KVPairs":
        return cls(wf, {
            rank: wf.array(arr, f"part{rank}", rank=rank)
            for rank, arr in enumerate(arrays)
        })

    # -- map ------------------------------------------------------------------
    def map(self, map_fn: Callable) -> "_Mapped":
        """``map_fn(values) -> (keys, values)`` applied on each node's data."""
        mapped = {}
        for rank, part in self.partitions.items():
            with bind.node(rank):
                mapped[rank] = self.wf.apply(
                    _map_partition, (part, map_fn), name="map", n_out=2
                )
        return _Mapped(self.wf, mapped)


class _Mapped:
    def __init__(self, wf: bind.Workflow, mapped: dict[int, tuple]):
        self.wf = wf
        self.mapped = mapped  # rank -> (keys BindArray, vals BindArray)

    def reduce(
        self,
        reduce_fn: Callable,
        n_buckets: int,
        owner: Optional[Callable[[int], int]] = None,
        combine_fn: Optional[Callable] = None,
        dtype=None,
    ) -> "Reduced":
        """Group by key into ``n_buckets``, ship each bucket to its owner node
        (the *implicit shuffle*), then apply ``reduce_fn(bucket_id, values)``.

        ``combine_fn`` (optional, the paper's ``combine``) pre-reduces each
        mapper-local bucket *on the mapper's node* before it travels —
        shrinking shuffle bytes exactly like Hadoop's combiner.  ``dtype``
        pins the value dtype of buckets that receive no data at all.
        """
        wf = self.wf
        # world size comes from the executor (the authority on how many
        # ranks exist), falling back to the workflow's declared size — not
        # from max(mapped)+1, which miscounts sparse rank dicts (mappers on
        # ranks {0, 5} must still spread reducers over the whole machine).
        executor = wf._executor
        n_nodes = executor.n_nodes if executor is not None else wf.n_nodes
        if owner is None:
            owner = lambda b: b * n_nodes // n_buckets  # contiguous ranges

        # 1. bucket extraction on the mapper's node
        pieces: dict[int, list] = {b: [] for b in range(n_buckets)}
        for rank, (keys, vals) in self.mapped.items():
            for b in range(n_buckets):
                with bind.node(rank):
                    piece = wf.apply(
                        _extract_bucket, (keys, vals, b, b + 1),
                        name=f"extract[{b}]",
                    )
                    if combine_fn is not None:
                        piece = wf.apply(combine_fn, (piece,), name="combine")
                pieces[b].append(piece)

        # 2. implicit shuffle + reduce: placing the reduce op on owner(b)
        #    makes the runtime move every piece there (tree-shipped when a
        #    piece has >1 consumer; plain p2p otherwise).
        buckets = {}
        for b in range(n_buckets):
            with bind.node(owner(b)):
                buckets[b] = wf.apply(
                    _reduce_bucket, (reduce_fn, b, dtype, *pieces[b]),
                    name=f"reduce[{b}]",
                )
        return Reduced(wf, buckets)


class Reduced:
    def __init__(self, wf: bind.Workflow, buckets: dict[int, bind.BindArray]):
        self.wf = wf
        self.buckets = buckets

    def collect(self) -> np.ndarray:
        """Gather buckets in key order to the host (implies sync)."""
        outs = [np.asarray(self.wf.fetch(self.buckets[b]))
                for b in sorted(self.buckets)]
        filled = [o for o in outs if o.size]
        if filled:
            return np.concatenate(filled)
        # keep the reducers' dtype even when every bucket came back empty
        return np.empty(0, dtype=outs[0].dtype) if outs else np.empty(0)
