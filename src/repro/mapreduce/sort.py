"""Integer sorting with MapReduce (paper Listing 2 + Fig. 5/6).

map: bucket = v >> (31 - LOG_BINS)   (radix prefix of a uniform 31-bit int)
reduce: per-bucket std::sort → globally sorted concatenation.
"""

from __future__ import annotations

import numpy as np

from repro import core as bind
from .engine import KVPairs


def sort_integers(
    values: np.ndarray,
    n_nodes: int,
    log_bins: int | None = None,
    executor: bind.LocalExecutor | None = None,
    backend: str = "serial",
) -> tuple[np.ndarray, bind.ExecutionStats]:
    """Sort ``values`` (int32/int64 ≥ 0) across ``n_nodes`` simulated nodes.

    ``backend`` selects the execution backend (``"serial"`` | ``"threads"``
    | ``"fused"``) when no ``executor`` is supplied.  Returns (sorted array,
    execution stats of the whole workflow — shuffle bytes, rounds,
    wavefronts — for the Fig. 5/6 scaling benchmark).
    """
    if log_bins is None:
        log_bins = max(1, int(np.ceil(np.log2(max(n_nodes, 2)))))
    n_bins = 1 << log_bins
    shift = 31 - log_bins

    def map_fn(vals):
        return (vals >> shift).astype(np.int64), vals

    def reduce_fn(_bucket, vals):
        return np.sort(vals)

    parts = np.array_split(values, n_nodes)
    executor = executor or bind.LocalExecutor(
        n_nodes, collective_mode="tree", backend=backend)
    with bind.Workflow(n_nodes=n_nodes, executor=executor) as wf:
        result = (
            KVPairs.from_arrays(wf, parts)
            .map(map_fn)
            .reduce(reduce_fn, n_buckets=n_bins,
                    owner=lambda b: b * n_nodes // n_bins,
                    dtype=values.dtype)
        )
        out = result.collect()
    return out, executor.stats
