"""Always-on serving runtime: admission queue + continuous cross-request batching.

Turns the run-to-completion :class:`~repro.core.scheduler.LocalExecutor`
into a service (ROADMAP item 1): clients submit *step closures* from any
thread and get back futures; a single background serving thread owns the
executor and one long-lived shared :class:`~repro.core.trace.Workflow`,
records each admitted step as its own program segment, and flushes a whole
batch of requests as ONE stitched program.

That one-flush-per-batch shape is where the existing machinery becomes
*continuous batching* for free:

* steps from different sessions touch disjoint refs, so their ops land in
  the same wavefront levels of the stitched plan; same-signature
  level-mates are exactly what ``backend="fused"`` stacks into one
  ``jit(vmap)`` dispatch (:class:`~repro.core.backends.FusedBatchBackend`)
  — N clients' decode steps cost one batched dispatch, not N;
* planning policy per flush: a *single* client's step stream replays its
  cached per-step plans at recorded segment boundaries
  (:func:`~repro.core.program.probe_plan` — the streaming client pays
  planning cost once even as its program grows); a *multi-client* batch
  plans the whole stitched program instead, because prefix splitting
  would fence each request's ops into their own sub-plan and forfeit
  cross-request fusion — those whole-batch plans are themselves
  relocatable-cached by structure.

Overload safety (this layer's failure-mode contract):

* **Backpressure** — the admission queue is bounded (``max_queue``) and
  each session has an in-flight budget (``max_inflight``); a submit that
  finds either full is *shed* with the retriable
  :class:`~repro.serve.session.RuntimeOverloaded` (or blocks up to
  ``timeout=`` seconds for space).  Load the service cannot absorb is
  refused at the door instead of growing an unbounded queue.
* **Flush-failure bisection** — every batch flush runs *input-atomic*
  (``protect_inputs``: the executor keeps the program's external inputs
  materialised through a failure), so when a multi-request flush fails
  the serving thread re-drives per-request sub-ranges through
  :meth:`~repro.core.scheduler.LocalExecutor.flush_slice` in a bisect
  loop: group probes narrow to the truly-failing request, only its
  session is poisoned, and every innocent request still completes with
  values identical to a serial execution.
* **Trace compaction** — after a flush, once the shared trace exceeds
  ``compact_threshold`` ops, the executed prefix is truncated and
  rebased (:meth:`~repro.core.scheduler.LocalExecutor.compact`), so a
  runtime serving forever holds O(live state), not O(steps ever served);
  the relocatable program-trace cache survives rebasing, so warm clients
  keep their zero-replan hits.

Threading model (single-writer): *recording is only ever done by the
serving thread*; client threads touch nothing but the admission queue and
their futures.  The executor's own lock additionally makes direct
``runtime.executor`` reads (stats, values) safe from test/monitor threads.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Optional

from ..core.scheduler import LocalExecutor
from ..core.trace import BindArray, Workflow
from .metrics import ServeMetrics
from .session import (RuntimeClosed, RuntimeOverloaded, ServeRequest,
                      Session, SessionPoisoned)

__all__ = ["ServingRuntime"]


class ServingRuntime:
    """Background-threaded serving frontend over one executor.

    Parameters
    ----------
    n_nodes, backend, mode, collective_mode:
        Forwarded to the owned :class:`LocalExecutor` (``backend="fused"``
        is the one that turns cross-request coalescing into single
        batched dispatches; any backend is correct).
    max_batch:
        Most requests admitted into one flush.
    admission_window:
        After the first queued request is seen, how long (seconds) the
        serving thread lingers for more before flushing — the knob trading
        a little p50 for batch width under bursty traffic.  0 flushes
        whatever is queued immediately.
    max_queue:
        Bound on the admission queue; a submit that finds it full is shed
        with :class:`RuntimeOverloaded` (reject-newest) unless it passed
        ``timeout=`` to block for space.  ``None`` = unbounded (the
        pre-backpressure behaviour).
    max_inflight:
        Per-session cap on unresolved requests (queued or executing);
        submits beyond it are shed the same way.  ``None`` = uncapped.
    prefix_cache:
        Forwarded to the executor (default True here — the streaming-client
        planning amortisation is the point of a serving runtime).
    compact_threshold:
        Once the shared trace reaches this many ops after a flush, the
        executed prefix is compacted away.  ``None`` disables compaction
        (the trace then grows with every request served).
    executor:
        Bring-your-own executor (overrides the construction knobs).
    autostart:
        ``False`` leaves the serving thread unstarted until
        :meth:`start` — deterministic batch composition for tests
        (everything submitted before ``start()`` lands in one batch, up
        to ``max_batch``).
    """

    def __init__(self, n_nodes: int = 1, backend: str = "fused",
                 mode: str = "plan", collective_mode: str = "tree",
                 max_batch: int = 32, admission_window: float = 0.002,
                 max_queue: Optional[int] = None,
                 max_inflight: Optional[int] = None,
                 prefix_cache: bool = True,
                 compact_threshold: Optional[int] = 512,
                 executor: Optional[LocalExecutor] = None,
                 autostart: bool = True):
        if executor is not None:
            self._ex = executor
        else:
            self._ex = LocalExecutor(n_nodes, collective_mode, mode=mode,
                                     backend=backend, stitch=True,
                                     prefix_cache=prefix_cache)
        self._prefix_cache = (prefix_cache if executor is None
                              else bool(executor.prefix_cache))
        self.max_batch = max(1, int(max_batch))
        self.admission_window = float(admission_window)
        self.max_queue = None if max_queue is None else max(1, int(max_queue))
        self.max_inflight = (None if max_inflight is None
                             else max(1, int(max_inflight)))
        self.compact_threshold = (None if compact_threshold is None
                                  else max(1, int(compact_threshold)))
        self._wf = Workflow(n_nodes=self._ex.n_nodes, executor=self._ex)
        self.metrics = ServeMetrics()
        self._queue: deque[ServeRequest] = deque()
        self._cv = threading.Condition()
        self._closed = False
        self._sessions = 0
        self._loop_error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._serve_loop, daemon=True,
                                        name="bind-serve")
        self._started = False
        if autostart:
            self.start()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServingRuntime":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop admitting, drain everything already queued, join the thread.

        A *started* runtime's serving thread drains the queue before
        exiting, so every admitted future resolves.  A never-started (or
        already-dead) runtime has no thread to drain: anything still
        queued is cancelled here — a queued future must never be left
        unresolved by ``close()``.
        """
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._started:
            self._thread.join(timeout)
        if not self._started or not self._thread.is_alive():
            with self._cv:
                leftovers = list(self._queue)
                self._queue.clear()
            for req in leftovers:
                if req.future.cancel():
                    self.metrics.requests_cancelled += 1
                elif not req.future.done():
                    req.future.set_exception(RuntimeClosed(
                        "runtime closed before this request ran"))

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    @property
    def executor(self) -> LocalExecutor:
        """The owned executor (its lock makes stats/value reads safe)."""
        return self._ex

    # -- client surface ------------------------------------------------------
    def session(self) -> Session:
        """Open a new client session."""
        with self._cv:
            self._sessions += 1
            return Session(self, self._sessions)

    def submit(self, session: Session, step: Callable[[Session], Any],
               timeout: Optional[float] = None):
        """Enqueue ``step`` to run against ``session``; returns a future.

        ``step(session)`` is *recorded* on the serving thread (it may
        create arrays via ``session.array`` and call ``@op`` functions);
        whatever handles it returns come back through the future as
        concrete payloads once the batch executes.  The future supports
        standard ``concurrent.futures`` semantics: ``cancel()`` works
        while the request is still queued (a cancelled request records
        nothing and never touches the executor), ``result(timeout=...)``
        raises ``TimeoutError`` without disturbing the in-flight request.

        Admission control: a full queue (``max_queue``) or session
        in-flight budget (``max_inflight``) sheds the submit with the
        retriable :class:`RuntimeOverloaded` — unless ``timeout`` is
        given, in which case the submit blocks up to that many seconds
        for space before shedding.  A closed runtime (or one whose
        serving thread died — then ``__cause__`` carries the loop's
        exception) raises :class:`RuntimeClosed`; a poisoned session
        raises :class:`SessionPoisoned`.
        """
        m = self.metrics
        deadline = (None if timeout is None
                    else time.monotonic() + max(0.0, timeout))
        with self._cv:
            while True:
                self._check_alive()
                if session.poisoned is not None:
                    m.requests_rejected += 1
                    raise SessionPoisoned(
                        f"session {session.sid} failed earlier; open a new "
                        f"one") from session.poisoned
                over = self._overload_reason(session)
                if over is None:
                    break
                remaining = (0.0 if deadline is None
                             else deadline - time.monotonic())
                if remaining <= 0.0:
                    m.requests_shed += 1
                    raise RuntimeOverloaded(over)
                self._cv.wait(min(remaining, 0.05))
            req = ServeRequest(session, step, time.perf_counter())
            session.inflight += 1
            req.future.add_done_callback(
                lambda _f, s=session: self._request_resolved(s))
            self._queue.append(req)
            m.requests_admitted += 1
            if len(self._queue) > m.queue_depth_hwm:
                m.queue_depth_hwm = len(self._queue)
            self._cv.notify()
        return req.future

    def _check_alive(self) -> None:
        # caller holds _cv
        if self._closed:
            if self._loop_error is not None:
                raise RuntimeClosed(
                    "serving thread died") from self._loop_error
            raise RuntimeClosed("serving runtime is closed")
        if self._started and not self._thread.is_alive():
            raise RuntimeClosed("serving thread is dead")

    def _overload_reason(self, session: Session) -> Optional[str]:
        # caller holds _cv
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            return (f"admission queue full ({self.max_queue}); retry after "
                    f"backoff")
        if (self.max_inflight is not None
                and session.inflight >= self.max_inflight):
            return (f"session {session.sid} already has "
                    f"{session.inflight} requests in flight")
        return None

    def _request_resolved(self, session: Session) -> None:
        # future done-callback (serving thread on resolve, client thread
        # on cancel): free the session's in-flight slot and wake any
        # submitter blocked on backpressure
        with self._cv:
            session.inflight -= 1
            self._cv.notify_all()

    # -- serving thread ------------------------------------------------------
    def _serve_loop(self) -> None:
        try:
            while True:
                batch = self._next_batch()
                if batch is None:
                    return
                try:
                    self._execute_batch(batch)
                except BaseException as e:
                    # a failure the bisection could not contain: poison
                    # the batch, never the serving thread
                    for req in batch:
                        if not req.future.done():
                            req.session.poisoned = e
                            self.metrics.requests_failed += 1
                            req.future.set_exception(e)
        except BaseException as e:
            self._die(e)

    def _die(self, e: BaseException) -> None:
        """An exception escaped the loop itself (e.g. out of
        ``_next_batch``): record it so the next ``submit`` surfaces
        :class:`RuntimeClosed` with this as ``__cause__``, and fail
        everything already queued — a silent dead thread with an
        accepting queue hangs clients forever."""
        with self._cv:
            self._loop_error = e
            self._closed = True
            leftovers = list(self._queue)
            self._queue.clear()
            self._cv.notify_all()
        for req in leftovers:
            if not req.future.done():
                self.metrics.requests_failed += 1
                req.future.set_exception(RuntimeClosed(
                    "serving thread died before this request ran"))

    def _next_batch(self) -> Optional[list]:
        with self._cv:
            while not self._queue:
                if self._closed:
                    return None
                self._cv.wait(0.05)
            if (self.admission_window > 0.0 and not self._closed
                    and len(self._queue) < self.max_batch):
                # linger briefly: under concurrent submitters the rest of
                # the burst usually lands within the window, widening the
                # fused buckets the flush will dispatch
                deadline = time.monotonic() + self.admission_window
                while len(self._queue) < self.max_batch and not self._closed:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0.0:
                        break
                    self._cv.wait(remaining)
            n = min(len(self._queue), self.max_batch)
            batch = [self._queue.popleft() for _ in range(n)]
            # queue slots freed: wake submitters blocked on backpressure
            self._cv.notify_all()
            return batch

    def _execute_batch(self, batch: list) -> None:
        ex, wf, m = self._ex, self._wf, self.metrics
        now = time.perf_counter()
        recorded: list[ServeRequest] = []
        # contiguous (request, start, end) tiles over the batch's op range
        # — the bisection's probe granularity.  ``request=None`` marks the
        # orphan ops of a closure that raised mid-recording (they cannot
        # be unrecorded; they are never re-driven).
        items: list[tuple[Optional[ServeRequest], int, int]] = []
        with wf.recording():
            for req in batch:
                if not req.future.set_running_or_notify_cancel():
                    m.requests_cancelled += 1
                    continue
                if req.session.poisoned is not None:
                    m.requests_rejected += 1
                    req.future.set_exception(SessionPoisoned(
                        f"session {req.session.sid} failed earlier"))
                    continue
                req.admitted_s = now
                start = len(wf.ops)
                try:
                    req.handles = _as_handles(req.step(req.session))
                except BaseException as e:
                    # bad request: poison only this session, and fence its
                    # partial ops into their own segment so a flush
                    # failure they cause is attributable to them
                    req.session.poisoned = e
                    m.requests_failed += 1
                    req.future.set_exception(e)
                    wf.sync()
                    if len(wf.ops) > start:
                        items.append((None, start, len(wf.ops)))
                    continue
                # one segment per request: the granularity at which the
                # prefix cache can replay this step's plan later — and at
                # which a failed flush is bisected
                wf.sync()
                if len(wf.ops) > start:
                    items.append((req, start, len(wf.ops)))
                recorded.append(req)
        if not recorded:
            try:
                # still materialise any orphan ops (dead work, executed
                # once); their sessions are already poisoned, so a
                # failure here is swallowed — the executor rolled back
                ex.flush(protect_inputs=True)
            except BaseException:
                pass
            self._maybe_compact()
            return
        m.flushes += 1
        n = len(recorded)
        if n >= 2:
            m.batched_flushes += 1
            m.coalesced_requests += n
        if n > m.max_batch:
            m.max_batch = n
        bisected = False
        try:
            # planning policy: a single client's step stream replays its
            # cached per-segment plans (pay planning once, however the
            # steps got grouped); a multi-client batch plans the whole
            # stitched program instead — prefix splitting would isolate
            # each request's ops in their own sub-plan and the fused
            # backend could never stack cross-request level-mates.  The
            # whole-program plan is itself relocatable-cached by
            # structure, so repeating batch shapes stop paying builds
            # too.  protect_inputs makes the flush input-atomic: a
            # failure leaves every request's inputs materialised for the
            # bisection below.
            ex.flush(prefix_cache=self._prefix_cache and n == 1,
                     protect_inputs=True)
        except BaseException as e:
            if len(items) == 1 and items[0][0] is not None:
                # single-request program: attribution is already known,
                # a probe would only re-run the failure
                req = items[0][0]
                req.session.poisoned = e
                m.requests_failed += 1
                req.future.set_exception(e)
            else:
                # the executor rolled the whole program back (flush
                # failure contract) but the trace still holds every
                # request's segment: narrow the blame by re-driving
                # sub-ranges
                self._bisect(items, e)
                bisected = True
        done = time.perf_counter()
        pre_completed = m.requests_completed
        for req in recorded:
            if req.future.done():
                continue
            try:
                values = tuple(
                    ex.value(h.ref.head) if isinstance(h, BindArray) else h
                    for h in req.handles)
            except BaseException as e:
                req.session.poisoned = e
                m.requests_failed += 1
                req.future.set_exception(e)
                continue
            m.latency.record(done - req.submitted_s)
            m.queue_latency.record(req.admitted_s - req.submitted_s)
            m.requests_completed += 1
            if not req.handles:
                req.future.set_result(None)
            elif len(req.handles) == 1:
                req.future.set_result(values[0])
            else:
                req.future.set_result(values)
        if bisected:
            m.requests_salvaged += m.requests_completed - pre_completed
        self._maybe_compact()

    def _bisect(self, items: list, err: BaseException) -> None:
        """Attribute a failed batch flush to the request(s) that caused it.

        Recursive group probing over the per-request tiles: a contiguous
        all-live group is re-driven as one :meth:`flush_slice` probe — on
        success the whole group is salvaged in a single shot; on failure
        it splits in half.  Probes run input-atomically themselves, so a
        failing *group* probe cannot GC an innocent member's inputs out
        from under the narrower probes that follow.  Orphan tiles and
        tiles of sessions poisoned earlier in this bisection are never
        re-driven: their outputs are unfetchable by construction (a
        poisoned session's later tile fails with ``SessionPoisoned``
        chained to the root cause).  Worst case cost is O(k·log n) probes
        for k culprits among n requests; the common one-bad-request case
        is ~2·log n.
        """
        ex, wf, m = self._ex, self._wf, self.metrics
        m.bisections += 1

        def fail(req: ServeRequest, e: BaseException) -> None:
            if req.session.poisoned is None:
                req.session.poisoned = e
            m.requests_failed += 1
            if not req.future.done():
                req.future.set_exception(e)

        def drive(group: list) -> None:
            if not group:
                return
            live = all(it[0] is not None and it[0].session.poisoned is None
                       for it in group)
            if live:
                try:
                    m.bisect_probes += 1
                    ex.flush_slice(wf, group[0][1], group[-1][2])
                    return           # whole group salvaged in one probe
                except BaseException as e:
                    if len(group) == 1:
                        fail(group[0][0], e)
                        return
            elif len(group) == 1:
                req = group[0][0]
                if req is not None and not req.future.done():
                    # same-session casualty: an earlier tile of this
                    # session failed in this very bisection
                    e = SessionPoisoned(
                        f"session {req.session.sid} failed earlier in "
                        f"this batch")
                    e.__cause__ = req.session.poisoned
                    fail(req, e)
                return
            mid = len(group) // 2
            drive(group[:mid])
            drive(group[mid:])

        drive(items)

    def _maybe_compact(self) -> None:
        wf, m = self._wf, self.metrics
        if len(wf.ops) > m.trace_ops_hwm:
            m.trace_ops_hwm = len(wf.ops)
        if (self.compact_threshold is not None
                and len(wf.ops) >= self.compact_threshold):
            removed = self._ex.compact(wf)
            if removed:
                m.compactions += 1
                m.ops_compacted += removed


def _as_handles(result: Any) -> tuple:
    """Normalise a step closure's return into a tuple of fetchables."""
    if result is None:
        return ()
    if isinstance(result, (tuple, list)):
        return tuple(result)
    return (result,)
