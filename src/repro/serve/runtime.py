"""Always-on serving runtime: admission queue + continuous cross-request batching.

Turns the run-to-completion :class:`~repro.core.scheduler.LocalExecutor`
into a service (ROADMAP item 1): clients submit *step closures* from any
thread and get back futures; a single background serving thread owns the
executor and one long-lived shared :class:`~repro.core.trace.Workflow`,
records each admitted step as its own program segment, and flushes a whole
batch of requests as ONE stitched program.

That one-flush-per-batch shape is where the existing machinery becomes
*continuous batching* for free:

* steps from different sessions touch disjoint refs, so their ops land in
  the same wavefront levels of the stitched plan; same-signature
  level-mates are exactly what ``backend="fused"`` stacks into one
  ``jit(vmap)`` dispatch (:class:`~repro.core.backends.FusedBatchBackend`)
  — N clients' decode steps cost one batched dispatch, not N;
* planning policy per flush: a *single* client's step stream replays its
  cached per-step plans at recorded segment boundaries
  (:func:`~repro.core.program.probe_plan` — the streaming client pays
  planning cost once even as its program grows); a *multi-client* batch
  plans the whole stitched program instead, because prefix splitting
  would fence each request's ops into their own sub-plan and forfeit
  cross-request fusion — those whole-batch plans are themselves
  relocatable-cached by structure;
* the executor's flush failure contract + per-session poisoning keep a
  bad request from taking the service down: the failed batch's sessions
  are poisoned, everyone else's payloads provably survive.

Threading model (single-writer): *recording is only ever done by the
serving thread*; client threads touch nothing but the admission queue and
their futures.  The executor's own lock additionally makes direct
``runtime.executor`` reads (stats, values) safe from test/monitor threads.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Optional

from ..core.scheduler import LocalExecutor
from ..core.trace import BindArray, Workflow
from .metrics import ServeMetrics
from .session import (RuntimeClosed, ServeRequest, Session, SessionPoisoned)

__all__ = ["ServingRuntime"]


class ServingRuntime:
    """Background-threaded serving frontend over one executor.

    Parameters
    ----------
    n_nodes, backend, mode, collective_mode:
        Forwarded to the owned :class:`LocalExecutor` (``backend="fused"``
        is the one that turns cross-request coalescing into single
        batched dispatches; any backend is correct).
    max_batch:
        Most requests admitted into one flush.
    admission_window:
        After the first queued request is seen, how long (seconds) the
        serving thread lingers for more before flushing — the knob trading
        a little p50 for batch width under bursty traffic.  0 flushes
        whatever is queued immediately.
    prefix_cache:
        Forwarded to the executor (default True here — the streaming-client
        planning amortisation is the point of a serving runtime).
    executor:
        Bring-your-own executor (overrides the construction knobs).
    autostart:
        ``False`` leaves the serving thread unstarted until
        :meth:`start` — deterministic batch composition for tests
        (everything submitted before ``start()`` lands in one batch, up
        to ``max_batch``).
    """

    def __init__(self, n_nodes: int = 1, backend: str = "fused",
                 mode: str = "plan", collective_mode: str = "tree",
                 max_batch: int = 32, admission_window: float = 0.002,
                 prefix_cache: bool = True,
                 executor: Optional[LocalExecutor] = None,
                 autostart: bool = True):
        if executor is not None:
            self._ex = executor
        else:
            self._ex = LocalExecutor(n_nodes, collective_mode, mode=mode,
                                     backend=backend, stitch=True,
                                     prefix_cache=prefix_cache)
        self._prefix_cache = (prefix_cache if executor is None
                              else bool(executor.prefix_cache))
        self.max_batch = max(1, int(max_batch))
        self.admission_window = float(admission_window)
        self._wf = Workflow(n_nodes=self._ex.n_nodes, executor=self._ex)
        self.metrics = ServeMetrics()
        self._queue: deque[ServeRequest] = deque()
        self._cv = threading.Condition()
        self._closed = False
        self._sessions = 0
        self._loop_error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._serve_loop, daemon=True,
                                        name="bind-serve")
        self._started = False
        if autostart:
            self.start()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServingRuntime":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop admitting, drain everything already queued, join the thread."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._started:
            self._thread.join(timeout)

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    @property
    def executor(self) -> LocalExecutor:
        """The owned executor (its lock makes stats/value reads safe)."""
        return self._ex

    # -- client surface ------------------------------------------------------
    def session(self) -> Session:
        """Open a new client session."""
        with self._cv:
            self._sessions += 1
            return Session(self, self._sessions)

    def submit(self, session: Session,
               step: Callable[[Session], Any]):
        """Enqueue ``step`` to run against ``session``; returns a future.

        ``step(session)`` is *recorded* on the serving thread (it may
        create arrays via ``session.array`` and call ``@op`` functions);
        whatever handles it returns come back through the future as
        concrete payloads once the batch executes.  The future supports
        standard ``concurrent.futures`` semantics: ``cancel()`` works
        while the request is still queued (a cancelled request records
        nothing and never touches the executor), ``result(timeout=...)``
        raises ``TimeoutError`` without disturbing the in-flight request.
        """
        with self._cv:
            if self._closed:
                raise RuntimeClosed("serving runtime is closed")
            if session.poisoned is not None:
                self.metrics.requests_rejected += 1
                raise SessionPoisoned(
                    f"session {session.sid} failed earlier; open a new one"
                ) from session.poisoned
            req = ServeRequest(session, step, time.perf_counter())
            self._queue.append(req)
            self.metrics.requests_admitted += 1
            self._cv.notify()
        return req.future

    # -- serving thread ------------------------------------------------------
    def _serve_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                self._execute_batch(batch)
            except BaseException as e:     # never kill the serving thread
                self._loop_error = e
                for req in batch:
                    if not req.future.done():
                        req.session.poisoned = e
                        req.future.set_exception(e)

    def _next_batch(self) -> Optional[list]:
        with self._cv:
            while not self._queue:
                if self._closed:
                    return None
                self._cv.wait(0.05)
            if (self.admission_window > 0.0 and not self._closed
                    and len(self._queue) < self.max_batch):
                # linger briefly: under concurrent submitters the rest of
                # the burst usually lands within the window, widening the
                # fused buckets the flush will dispatch
                deadline = time.monotonic() + self.admission_window
                while len(self._queue) < self.max_batch and not self._closed:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0.0:
                        break
                    self._cv.wait(remaining)
            n = min(len(self._queue), self.max_batch)
            return [self._queue.popleft() for _ in range(n)]

    def _execute_batch(self, batch: list) -> None:
        ex, wf, m = self._ex, self._wf, self.metrics
        now = time.perf_counter()
        recorded: list[ServeRequest] = []
        with wf.recording():
            for req in batch:
                if not req.future.set_running_or_notify_cancel():
                    m.requests_cancelled += 1
                    continue
                if req.session.poisoned is not None:
                    m.requests_rejected += 1
                    req.future.set_exception(SessionPoisoned(
                        f"session {req.session.sid} failed earlier"))
                    continue
                req.admitted_s = now
                try:
                    req.handles = _as_handles(req.step(req.session))
                except BaseException as e:
                    # bad request: poison only this session.  Ops it
                    # recorded before raising stay in the trace (they
                    # cannot be unrecorded) and execute as dead work once.
                    req.session.poisoned = e
                    m.requests_failed += 1
                    req.future.set_exception(e)
                    continue
                # one segment per request: the granularity at which the
                # prefix cache can replay this step's plan later
                wf.sync()
                recorded.append(req)
        # cover trailing ops of a closure that raised after recording
        wf.sync()
        if not recorded:
            ex.flush()      # still materialise any orphan ops
            return
        m.flushes += 1
        n = len(recorded)
        if n >= 2:
            m.batched_flushes += 1
            m.coalesced_requests += n
        if n > m.max_batch:
            m.max_batch = n
        try:
            # planning policy: a single client's step stream replays its
            # cached per-segment plans (pay planning once, however the
            # steps got grouped); a multi-client batch plans the whole
            # stitched program instead — prefix splitting would isolate
            # each request's ops in their own sub-plan and the fused
            # backend could never stack cross-request level-mates.  The
            # whole-program plan is itself relocatable-cached by
            # structure, so repeating batch shapes stop paying builds too.
            ex.flush(prefix_cache=self._prefix_cache and n == 1)
        except BaseException as e:
            # the executor rolled itself back (flush failure contract);
            # attribution inside the batch is not knowable here, so the
            # whole batch's sessions are poisoned — narrower attribution
            # is a recorded follow-up.  Other sessions' payloads survive.
            for req in recorded:
                req.session.poisoned = e
                m.requests_failed += 1
                req.future.set_exception(e)
            return
        done = time.perf_counter()
        for req in recorded:
            try:
                values = tuple(
                    ex.value(h.ref.head) if isinstance(h, BindArray) else h
                    for h in req.handles)
            except BaseException as e:
                req.session.poisoned = e
                m.requests_failed += 1
                req.future.set_exception(e)
                continue
            m.latency.record(done - req.submitted_s)
            m.queue_latency.record(req.admitted_s - req.submitted_s)
            m.requests_completed += 1
            if not req.handles:
                req.future.set_result(None)
            elif len(req.handles) == 1:
                req.future.set_result(values[0])
            else:
                req.future.set_result(values)


def _as_handles(result: Any) -> tuple:
    """Normalise a step closure's return into a tuple of fetchables."""
    if result is None:
        return ()
    if isinstance(result, (tuple, list)):
        return tuple(result)
    return (result,)
