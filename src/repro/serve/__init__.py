"""Always-on serving layer over the Bind executor (ROADMAP item 1).

Usage::

    from repro.serve import ServingRuntime

    with ServingRuntime(backend="fused") as rt:
        s = rt.session()
        fut = s.submit(lambda sess: decode_step(sess))
        value = fut.result()
        print(rt.metrics.summary())

See :mod:`repro.serve.runtime` for the architecture.
"""

from .metrics import ServeMetrics
from .runtime import ServingRuntime
from .session import (RuntimeClosed, RuntimeOverloaded, ServeError,
                      ServeRequest, Session, SessionPoisoned)

__all__ = ["ServingRuntime", "ServeMetrics", "Session", "ServeRequest",
           "ServeError", "RuntimeClosed", "RuntimeOverloaded",
           "SessionPoisoned"]
