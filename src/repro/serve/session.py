"""Client-facing serving primitives: sessions, requests, failure types.

A :class:`Session` is one client's stream of requests against a
:class:`~repro.serve.runtime.ServingRuntime`.  Clients never touch the
executor or the shared workflow directly — they submit *step closures*
that the serving thread records (single-writer discipline), so arbitrary
numbers of client threads can stream steps concurrently without racing on
the trace.

The blast radius of a failure is deliberately per-session, not
per-service: a step closure that raises (bad request) or an op body that
fails mid-flush poisons the session(s) the flush-failure bisection
attributes the failure to — their later submits raise
:class:`SessionPoisoned` — while the runtime, the executor, and every
other session keep serving (the executor's flush failure contract
guarantees their payloads survive).  Overload is likewise surfaced, not
absorbed: when the admission queue or a session's in-flight budget is
full, ``submit`` sheds the request with :class:`RuntimeOverloaded` — a
*retriable* condition, unlike the terminal :class:`RuntimeClosed` /
:class:`SessionPoisoned`.
"""

from __future__ import annotations

import concurrent.futures
from typing import Any, Callable, Optional


class ServeError(RuntimeError):
    """Base class for serving-layer failures."""


class RuntimeClosed(ServeError):
    """The serving runtime was shut down (or its serving thread died —
    then ``__cause__`` carries the loop's exception); no further submits
    are accepted."""


class RuntimeOverloaded(ServeError):
    """The request was shed at admission: the bounded queue (or the
    session's in-flight budget) is full.  Retriable — back off and
    resubmit; the session is *not* poisoned."""


class SessionPoisoned(ServeError):
    """A previous step of this session failed; its state is untrusted.

    Carries the original failure as ``__cause__``.  Other sessions are
    unaffected — open a fresh session to continue.
    """


class Session:
    """One client's stream of steps over runtime-resident state.

    ``state`` is a scratch dict for the client's step closures (the
    conventional home for its :class:`~repro.core.trace.BindArray`
    handles — e.g. the KV cache of a decode loop).  Step closures run *on
    the serving thread* with the shared workflow active, so inside one
    they may call ``self.array(...)`` and any recorded ``@op``.

    ``inflight`` counts this session's unresolved requests (queued or
    executing); the runtime's per-session cap sheds submits beyond it.
    """

    __slots__ = ("runtime", "sid", "state", "poisoned", "inflight")

    def __init__(self, runtime, sid: int):
        self.runtime = runtime
        self.sid = sid
        self.state: dict = {}
        self.poisoned: Optional[BaseException] = None
        self.inflight = 0

    def array(self, value: Any, name: str = "", rank: int = 0):
        """Create a runtime-resident array (serving thread only — call
        from inside a step closure)."""
        return self.runtime._wf.array(
            value, name=f"s{self.sid}.{name}" if name else f"s{self.sid}",
            rank=rank)

    def submit(self, step: Callable[["Session"], Any],
               timeout: Optional[float] = None
               ) -> concurrent.futures.Future:
        """Enqueue one step; returns its future (see ``ServingRuntime.submit``)."""
        return self.runtime.submit(self, step, timeout=timeout)

    def __repr__(self) -> str:
        status = "poisoned" if self.poisoned is not None else "ok"
        return f"Session({self.sid}, {status})"


class ServeRequest:
    """One admitted step: the closure, its future, and latency timestamps.

    ``submitted_s`` is stamped at submit (queue time starts), ``admitted_s``
    when the serving thread picks the request into a batch; the request
    latency recorded on completion is end-to-end (submit → value ready),
    the number a client actually experiences.
    """

    __slots__ = ("session", "step", "future", "submitted_s", "admitted_s",
                 "handles")

    def __init__(self, session: Session, step: Callable, submitted_s: float):
        self.session = session
        self.step = step
        self.future: concurrent.futures.Future = concurrent.futures.Future()
        self.submitted_s = submitted_s
        self.admitted_s = 0.0
        self.handles: tuple = ()
