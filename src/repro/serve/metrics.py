"""Serving-side observability: request counters + latency quantiles.

:class:`ServeMetrics` is the service twin of
:class:`~repro.core.stats.ExecutionStats` — the executor accounts ops,
transfers and cache traffic; this accounts *requests*: admissions, sheds,
completions, failures, how often flushes actually coalesced work across
requests, and end-to-end/queue latency distributions
(:class:`~repro.core.stats.LatencyStats`).  The batching effectiveness
counters are what the serving tests and bench assert: a runtime absorbing
N concurrent one-step clients should show ``coalesced_requests`` close to
N and ``batched_flushes >= 1``, while the one-at-a-time arm shows 0.
Overload is observable, not mysterious: ``requests_shed`` and
``queue_depth_hwm`` say how hard admission pushed back, ``bisections`` /
``requests_salvaged`` say how often a failed batch was narrowed to its
true culprit, and ``compactions`` / ``trace_ops_hwm`` bound the shared
trace's growth.
"""

from __future__ import annotations

import dataclasses

from ..core.stats import LatencyStats


@dataclasses.dataclass
class ServeMetrics:
    """Counters and latency distributions for one serving runtime."""

    requests_admitted: int = 0
    requests_completed: int = 0
    requests_failed: int = 0
    requests_cancelled: int = 0     # cancelled while still queued
    requests_rejected: int = 0      # refused at admission (poisoned session)
    requests_shed: int = 0          # refused at admission (overload)
    queue_depth_hwm: int = 0        # admission-queue high-water mark
    # flush coalescing: every executor flush issued by the serving loop;
    # "batched" ones carried >= 2 requests' segments in one program
    flushes: int = 0
    batched_flushes: int = 0
    coalesced_requests: int = 0     # requests that shared their flush
    max_batch: int = 0              # widest batch observed
    # flush-failure bisection: failed multi-request flushes narrowed by
    # re-driving per-request sub-ranges (probes = flush_slice calls)
    bisections: int = 0
    bisect_probes: int = 0
    requests_salvaged: int = 0      # completed despite a failed batch flush
    # trace compaction (bounded-memory serving)
    compactions: int = 0
    ops_compacted: int = 0
    trace_ops_hwm: int = 0          # widest shared trace observed
    # end-to-end (submit -> result ready) and queue (submit -> admitted)
    latency: LatencyStats = dataclasses.field(default_factory=LatencyStats)
    queue_latency: LatencyStats = dataclasses.field(
        default_factory=LatencyStats)

    def summary(self) -> dict:
        """One dashboard/bench row (latencies in milliseconds)."""
        return {
            "requests_admitted": self.requests_admitted,
            "requests_completed": self.requests_completed,
            "requests_failed": self.requests_failed,
            "requests_cancelled": self.requests_cancelled,
            "requests_rejected": self.requests_rejected,
            "requests_shed": self.requests_shed,
            "queue_depth_hwm": self.queue_depth_hwm,
            "flushes": self.flushes,
            "batched_flushes": self.batched_flushes,
            "coalesced_requests": self.coalesced_requests,
            "max_batch": self.max_batch,
            "bisections": self.bisections,
            "bisect_probes": self.bisect_probes,
            "requests_salvaged": self.requests_salvaged,
            "compactions": self.compactions,
            "ops_compacted": self.ops_compacted,
            "trace_ops_hwm": self.trace_ops_hwm,
            "latency_ms": self.latency.summary(),
            "queue_ms": self.queue_latency.summary(),
        }
