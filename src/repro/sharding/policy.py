"""Sharding policy: how every tensor in the system maps onto the mesh.

Scheme (uniform across all ten architectures — chosen so no architecture
hits a head-divisibility wall; see DESIGN.md §4):

* **Parameters** — flat FSDP (ZeRO-3): each tensor's largest eligible dim is
  sharded over ``fsdp_axes`` = ("data", "model") — 256-way within a pod,
  replicated across pods (gradient sync crosses pods hierarchically).
* **Activations** — batch over ``dp_axes`` = ("pod", "data"); sequence over
  "model" (context/sequence parallelism).  Attention keeps queries
  seq-sharded and gathers the (GQA-small) K/V over "model".
* **Logits** — vocab-parallel over "model" (sequence unshards there), with
  the loss computed in sequence chunks so full logits never materialise.
* **MoE** — expert dim over "model" when divisible (EP all_to_all inside a
  shard_map), otherwise experts replicated over "model" and computed on the
  local sequence shard.

The policy object is consumed by (a) ``shard_act`` tags inside model code,
(b) ``param_specs`` for in/out shardings of the jitted steps, (c) the KV
cache layout for decode.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    dp_axes: tuple[str, ...]          # batch axes, outermost first
    model_axis: Optional[str]         # tensor/sequence axis (None -> off)
    fsdp_axes: tuple[str, ...]        # parameter flat-sharding axes
    batch_sharded: bool = True        # False for global_batch=1 (long_500k)
    seq_sharded: bool = True
    # params_tp (decode serving): weights live TP-sharded over the model
    # axis (column-parallel in / row-parallel out) + FSDP over data only —
    # no per-step weight regathers over the model axis (§Perf C1)
    params_tp: bool = False
    # tensors below this many elements replicate (tiny-tensor FSDP causes
    # involuntary SPMD remats + pointless gathers; §Perf A2)
    min_shard_elems: int = 65536

    # -- sizes ------------------------------------------------------------
    @property
    def fsdp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.fsdp_axes]))

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.model_axis else 1

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    # -- activations --------------------------------------------------------
    def activation_spec(self, tag: str, ndim: int) -> Optional[P]:
        dp = self.dp_axes if self.batch_sharded else None
        sp = self.model_axis if self.seq_sharded else None
        if tag == "residual":        # (B, S, d)
            return P(dp, sp, None)
        if tag == "tokens":          # (B, S)
            return P(dp, sp)
        if tag == "kv_gathered":     # (B, KV, S, hd) — gather seq over model
            return P(dp, None, None, None)
        if tag == "seq_gathered":    # (B, S, d) — sLSTM: time scan needs the
            return P(dp, None, None)  # whole sequence (serial recurrence)
        if tag == "ffn_hidden":      # (B, S, ff)
            return P(dp, sp, None)
        if tag == "logits_vp":       # (B, S_chunk, V) vocab-parallel
            return P(dp, None, sp)
        if tag == "logits_seq":      # (B, S, V) seq-sharded, full vocab
            return P(dp, sp, None)
        if tag == "kv_cache":        # (B, KV, S_max, hd) — seq-sharded cache
            return P(dp, None, sp, None)
        if tag == "recurrent_state":  # (B, width) / (B, H, dk, dv)
            return (P(dp, sp) if ndim == 2
                    else P(dp, None, sp, None) if ndim == 4
                    else P(dp, None, sp))
        if tag == "expert_buffer":   # (E, C, d) — EP
            return P(sp, None, None)
        return None

    def activation_sharding(self, tag: str, ndim: int):
        spec = self.activation_spec(tag, ndim)
        return NamedSharding(self.mesh, spec if spec is not None else P())

    # -- parameters -----------------------------------------------------------
    def param_spec(self, shape: tuple[int, ...], *, stacked: bool = False,
                   expert_dim: Optional[int] = None) -> P:
        """Flat-FSDP: shard the largest dim divisible by the axis product.

        ``stacked`` marks a leading scan (layer-group) dim that must stay
        unsharded; ``expert_dim`` pins MoE expert weights' expert axis to the
        model axis (EP) with FSDP falling back to the remaining axes.
        """
        start = 1 if stacked else 0
        dims = list(range(start, len(shape)))
        spec: list[Any] = [None] * len(shape)
        n_elems = int(np.prod(shape)) if shape else 0
        if len(shape) - start < 2 or n_elems < self.min_shard_elems:
            return P(*spec)          # tiny / 1-D tensors replicate (A2)
        if expert_dim is not None and self.model_axis:
            spec[expert_dim] = self.model_axis
            dims.remove(expert_dim)
            axes = tuple(a for a in self.fsdp_axes if a != self.model_axis)
        else:
            axes = self.fsdp_axes
        if axes:
            size = int(np.prod([self.mesh.shape[a] for a in axes]))
            cands = [d for d in dims if shape[d] % size == 0 and shape[d] >= size]
            if cands:
                d = max(cands, key=lambda i: shape[i])
                spec[d] = axes if len(axes) > 1 else axes[0]
            else:
                # fall back to the single largest axis that divides
                for ax in sorted(axes, key=lambda a: -self.mesh.shape[a]):
                    n = self.mesh.shape[ax]
                    cands = [d for d in dims if shape[d] % n == 0 and shape[d] >= n]
                    if cands:
                        d = max(cands, key=lambda i: shape[i])
                        spec[d] = ax
                        break
        return P(*spec)

    def param_sharding(self, shape, **kw) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_spec(shape, **kw))

    # TP placement by weight role: column-parallel projections shard their
    # output dim, row-parallel ones their input dim (Megatron convention)
    _TP_COL = ("wq", "wk", "wv", "w_gate", "w_up", "ffn_up", "w_x", "w_y",
               "w_gates", "w_if", "lm_head")
    _TP_ROW = ("wo", "w_down", "ffn_down", "w_out")

    def _tp_spec(self, keys, shape, stacked: bool):
        """TP serving placement: weights shard over the model axis only and
        stay *resident* (replicated over data — a 1/model_size shard fits
        HBM for every assigned arch), so decode steps move zero weight
        bytes (§Perf C1/C2)."""
        last = keys[-1] if keys else ""
        m, n_m = self.model_axis, self.model_size
        o = 1 if stacked else 0
        if len(shape) - o != 2 or m is None:
            return None
        spec: list[Any] = [None] * len(shape)
        if last in self._TP_COL and shape[o + 1] % n_m == 0:
            spec[o + 1] = m
            return P(*spec)
        if last in self._TP_ROW and shape[o] % n_m == 0:
            spec[o] = m
            return P(*spec)
        if last == "emb" and shape[o + 1] % n_m == 0:
            spec[o + 1] = m        # d_model-sharded: lookup gathers 1/16
            return P(*spec)
        return None

    def tree_param_shardings(self, tree) -> Any:
        """Shardings for a parameter pytree (heuristics by path)."""
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        treedef = jax.tree_util.tree_structure(tree)
        specs = []
        for path, leaf in flat:
            keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
            stacked = "groups" in keys
            if self.params_tp:
                tp = self._tp_spec(keys, leaf.shape, stacked)
                if tp is not None:
                    specs.append(NamedSharding(self.mesh, tp))
                    continue
            expert_dim = None
            if any(k in ("experts",) for k in keys if isinstance(k, str)):
                # expert weights: (..., E, d_in, d_out); expert dim is 0
                # (or 1 when stacked)
                e_ax = 1 if stacked else 0
                if leaf.ndim > e_ax and leaf.shape[e_ax] % max(self.model_size, 1) == 0 \
                        and self.model_size > 1:
                    expert_dim = e_ax
            specs.append(self.param_sharding(
                leaf.shape, stacked=stacked, expert_dim=expert_dim))
        return jax.tree_util.tree_unflatten(treedef, specs)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())


def make_policy(
    mesh: Mesh,
    *,
    batch_sharded: bool = True,
    seq_sharded: bool = True,
    fsdp: bool = True,
    params_tp: bool = False,
) -> ShardingPolicy:
    """Derive the standard policy from a mesh's axis names."""
    names = mesh.axis_names
    model_axis = "model" if "model" in names else None
    dp = tuple(a for a in names if a in ("pod", "data"))
    fsdp_axes = tuple(a for a in names if a in ("data", "model")) if fsdp else ()
    return ShardingPolicy(
        mesh=mesh,
        dp_axes=dp,
        model_axis=model_axis,
        fsdp_axes=fsdp_axes,
        batch_sharded=batch_sharded,
        seq_sharded=seq_sharded,
        params_tp=params_tp,
    )
