from .constraints import shard_act, use_policy, current_policy
from .policy import ShardingPolicy, make_policy

__all__ = [
    "shard_act", "use_policy", "current_policy", "ShardingPolicy", "make_policy",
]
