"""Activation-sharding hooks — Bind's scope-guard idea at the mesh level.

Model code never mentions a mesh; it tags activations with *semantic* names
(``"residual"``, ``"kv_gathered"``, ``"ffn_hidden"``).  When a
:class:`~repro.sharding.policy.ShardingPolicy` is active (a context manager,
the moral equivalent of the paper's ``bind::node`` scope guards), each tag
resolves to a ``with_sharding_constraint``; with no policy active the hooks
are identity, so the same model runs on one CPU device in the tests.
"""

from __future__ import annotations

import contextlib
import threading

import jax

_TLS = threading.local()


def current_policy():
    return getattr(_TLS, "policy", None)


@contextlib.contextmanager
def use_policy(policy):
    prev = current_policy()
    _TLS.policy = policy
    try:
        yield policy
    finally:
        _TLS.policy = prev


def shard_act(x: jax.Array, tag: str) -> jax.Array:
    pol = current_policy()
    if pol is None:
        return x
    spec = pol.activation_spec(tag, x.ndim)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(pol.mesh, spec)
    )


def shard_param_slice(tree):
    """Re-pin a scan-sliced layer's parameters to their FSDP layout.

    Without this the SPMD partitioner prefers gathering the *whole stacked*
    (L, ...) tensor before slicing — an 18 GiB resident gather for
    qwen2.5's stacked FFN.  Constraining the slice keeps the stack sharded
    at rest and gathers one layer just-in-time (§Perf iteration A4).
    """
    pol = current_policy()
    if pol is None:
        return tree

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, x in flat:
        if not hasattr(x, "ndim") or x.ndim < 2:
            out.append(x)
            continue
        spec = None
        if pol.params_tp:
            keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
            spec = pol._tp_spec(keys, x.shape, False)
        if spec is None:
            spec = pol.param_spec(x.shape)
        out.append(jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(pol.mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
